"""Distribution integration tests.

The production 256/512-chip meshes are exercised by
``launch/dryrun.py`` (its own process, 512 forced host devices).  Here
we run a REDUCED mesh (8 forced devices, 2x4) in a subprocess so the
pytest process keeps its single CPU device, proving the same
pjit/shard_map plumbing end to end — including a real
numerically-checked sharded run, not just lowering.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.launch import sharding as shd
from repro.models import transformer as tfm
from repro.training import AdamW, make_train_step
mesh = jax.make_mesh((2, 4), ("data", "model"))
"""


def test_sharded_train_step_matches_single_device():
    """jit(train_step) on a 2x4 mesh == single-device reference."""
    code = _PRELUDE + textwrap.dedent("""
        cfg = get_smoke_config("internlm2-20b").replace(
            dtype="float32", remat=False)
        params = tfm.init_lm(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        state = opt.init(params)
        step = make_train_step(cfg, opt)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)}

        # single-device reference
        p_ref, s_ref, m_ref = jax.jit(step)(params, state, batch)

        p_spec = shd.param_specs(params, mesh)
        p_sh = shd.to_named(p_spec, mesh)
        b_sh = {"tokens": NamedSharding(mesh, P("data", None))}
        o_sh = shd.to_named(shd.param_specs(state, mesh), mesh)
        stepd = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
        p_d, s_d, m_d = stepd(
            jax.device_put(params, p_sh), jax.device_put(state, o_sh),
            jax.device_put(batch, b_sh))
        err = abs(float(m_ref["loss"]) - float(m_d["loss"]))
        werr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                                   jax.tree_util.tree_leaves(p_d)))
        print(json.dumps({"loss_err": err, "w_err": werr}))
    """)
    res = _run(code)
    assert res["loss_err"] < 1e-4
    assert res["w_err"] < 1e-3


def test_sharded_decode_matches_single_device():
    code = _PRELUDE + textwrap.dedent("""
        cfg = get_smoke_config("granite-moe-3b-a800m").replace(
            dtype="float32", remat=False, capacity_factor=4.0)
        params = tfm.init_lm(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 9),
                                  0, cfg.vocab)
        cache = tfm.init_cache(cfg, 4, 32, dtype=jnp.float32)
        _, cache = tfm.prefill(cfg, params, toks[:, :8], cache)
        ref, _ = tfm.decode_step(cfg, params, toks[:, 8:9], cache, 8)

        p_sh = shd.to_named(shd.param_specs(params, mesh), mesh)
        c_sh = shd.to_named(shd.cache_specs(cfg, cache, mesh, 4), mesh)
        t_sh = NamedSharding(mesh, P("data", None))
        r_sh = NamedSharding(mesh, P())
        fn = jax.jit(lambda p, t, c, pos: tfm.decode_step(cfg, p, t, c,
                                                          pos),
                     in_shardings=(p_sh, t_sh, c_sh, r_sh))
        out, _ = fn(jax.device_put(params, p_sh),
                    jax.device_put(toks[:, 8:9], t_sh),
                    jax.device_put(cache, c_sh),
                    jax.device_put(jnp.asarray(8), r_sh))
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    assert _run(code)["err"] < 1e-3


def test_seq_sharded_decode_batch1():
    """long-context pattern: batch=1, KV sequence sharded over data."""
    code = _PRELUDE + textwrap.dedent("""
        cfg = get_smoke_config("internlm2-20b").replace(
            dtype="float32", remat=False)
        params = tfm.init_lm(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 17),
                                  0, cfg.vocab)
        cache = tfm.init_cache(cfg, 1, 32, dtype=jnp.float32)
        _, cache = tfm.prefill(cfg, params, toks[:, :16], cache)
        ref, _ = tfm.decode_step(cfg, params, toks[:, 16:17], cache, 16)

        p_sh = shd.to_named(shd.param_specs(params, mesh), mesh)
        c_spec = shd.cache_specs(cfg, cache, mesh, 1)
        assert c_spec.layers.kv.k[2] == "data", c_spec.layers.kv.k
        c_sh = shd.to_named(c_spec, mesh)
        t_sh = NamedSharding(mesh, P(None, None))
        r_sh = NamedSharding(mesh, P())
        fn = jax.jit(lambda p, t, c, pos: tfm.decode_step(cfg, p, t, c,
                                                          pos),
                     in_shardings=(p_sh, t_sh, c_sh, r_sh))
        out, _ = fn(jax.device_put(params, p_sh),
                    jax.device_put(toks[:, 16:17], t_sh),
                    jax.device_put(cache, c_sh),
                    jax.device_put(jnp.asarray(16), r_sh))
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    assert _run(code)["err"] < 1e-3


@pytest.mark.slow
def test_production_mesh_lowering_sample():
    """One full production-mesh (256-chip) lowering as a test — the
    complete matrix lives in results/dryrun (launch/dryrun.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "stablelm-3b", "--shape", "decode_32k", "--mesh", "single",
         "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "1 ok" in out.stdout
