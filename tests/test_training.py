"""Training substrate: optimizer, schedule, data, checkpoint, and an
integration test that the classifier actually learns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import distilbert
from repro.training import (AdamW, ClassificationData, cosine_schedule,
                            global_norm, lm_batches, make_train_step,
                            train_classifier)
from repro.training import checkpoint
from repro.configs import get_smoke_config
from repro.models import transformer as tfm

KEY = jax.random.PRNGKey(0)


def test_adamw_minimises_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda p: 2 * p, params)
        params, state, gn = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clips_gradients():
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, gn = opt.update(huge, state, params)
    assert float(gn) > 1.0                      # reported pre-clip norm


def test_cosine_schedule_shape():
    s0 = float(cosine_schedule(0, warmup=10, total=100))
    s10 = float(cosine_schedule(10, warmup=10, total=100))
    s100 = float(cosine_schedule(100, warmup=10, total=100, floor=0.1))
    assert s0 < 0.2 and abs(s10 - 1.0) < 1e-5
    assert abs(s100 - 0.1) < 1e-2


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones(9)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(13.0))


def test_lm_batches_learnable_structure():
    gen = lm_batches(vocab=512, batch=4, seq_len=32, seed=0)
    b = next(gen)
    assert b.shape == (4, 33) and b.dtype == np.int32
    assert b.max() < 512


def test_classification_difficulty_controls_separability():
    ds = ClassificationData(vocab=500, seq_len=32)
    easy_t, easy_l, _ = ds.sample(200, difficulty=np.full(200, 0.1))
    hard_t, hard_l, _ = ds.sample(200, difficulty=np.full(200, 0.98))
    # count class-token hits as a crude separability proxy
    def hits(toks, labels):
        k = ds.n_class_tokens
        lo = labels[:, None] * k
        return np.mean((toks >= lo) & (toks < lo + k))
    assert hits(easy_t, easy_l) > hits(hard_t, hard_l) + 0.3


def test_classifier_learns():
    cfg = distilbert.config(n_layers=2, d_model=64, n_heads=4, d_ff=128,
                            vocab=600, max_pos=32)
    params = distilbert.init(cfg, KEY)
    data = ClassificationData(vocab=600, seq_len=24)
    params, log = train_classifier(cfg, params, data.train_batches(32),
                                   steps=40, log_every=10, verbose=False)
    assert log[-1]["ce"] < log[0]["ce"]


def test_lm_train_step_loss_decreases():
    cfg = get_smoke_config("llama3-405b")
    params = tfm.init_lm(cfg, KEY)
    opt = AdamW(lr=3e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, warmup=1))
    gen = lm_batches(vocab=cfg.vocab, batch=8, seq_len=24, seed=1)
    first = last = None
    batch0 = {"tokens": jnp.asarray(next(gen))}
    for i in range(15):
        params, state, m = step(params, state, batch0)  # overfit one batch
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.2


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("granite-moe-3b-a800m")
    params = tfm.init_lm(cfg, KEY)
    opt = AdamW()
    state = opt.init(params)
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"p": params, "o": state}, metadata={"step": 3})
    back = checkpoint.load_into(path, {"p": params, "o": state})
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves({"p": params, "o": state})):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        checkpoint.load_into(path, {"b": jnp.ones(3)})


def test_remat_policies_agree():
    """All remat policies compute identical losses (they only change
    what is recomputed, never the math) — §Perf pair F."""
    base = get_smoke_config("stablelm-3b")
    losses = []
    for pol in ("full", "dots", "none"):
        cfg = base.replace(remat=pol != "none", remat_policy=pol)
        params = tfm.init_lm(cfg, KEY)
        opt = AdamW(lr=1e-3)
        step = jax.jit(make_train_step(cfg, opt, warmup=1))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3),
                                              (2, 17), 0, cfg.vocab)}
        _, _, m = step(params, opt.init(params), batch)
        losses.append(float(m["loss"]))
    assert max(losses) - min(losses) < 1e-4
