"""Continuous batching: per-row decode positions + slot splicing must
reproduce exactly what isolated lockstep generation produces, and the
in-graph fused loop must reproduce exactly what the legacy per-step
host loop produces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import AdmissionController, DecayingThreshold
from repro.models import transformer as tfm
from repro.serving.continuous import (ContinuousBatchingEngine,
                                      GenRequest, _leaf_batch_axis,
                                      _splice, cache_batch_axes,
                                      slot_write)

KEY = jax.random.PRNGKey(0)


def test_per_row_positions_match_lockstep():
    """decode_step with a pos VECTOR must agree with scalar pos when
    all rows share the position (regression for the vector path)."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (3, 9), 0, cfg.vocab)
    c1 = tfm.init_cache(cfg, 3, 32)
    _, c1 = tfm.prefill(cfg, params, toks[:, :8], c1)
    c2 = jax.tree_util.tree_map(lambda x: x, c1)
    lg_s, _ = tfm.decode_step(cfg, params, toks[:, 8:9], c1, 8)
    lg_v, _ = tfm.decode_step(cfg, params, toks[:, 8:9], c2,
                              jnp.array([8, 8, 8]))
    np.testing.assert_allclose(
        np.asarray(lg_s, np.float32), np.asarray(lg_v, np.float32),
        rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["stablelm-3b", "minicpm3-4b"])
def test_per_row_positions_staggered(arch):
    """Rows at DIFFERENT positions: each must match its own isolated
    batch-1 decode."""
    cfg = get_smoke_config(arch).replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    seqs = [jax.random.randint(jax.random.PRNGKey(i), (1, 6 + 2 * i),
                               0, cfg.vocab) for i in range(2)]
    # isolated references
    refs = []
    for s in seqs:
        c = tfm.init_cache(cfg, 1, 32)
        _, c = tfm.prefill(cfg, params, s[:, :-1], c)
        lg, _ = tfm.decode_step(cfg, params, s[:, -1:], c,
                                s.shape[1] - 1)
        refs.append(np.asarray(lg[0, 0], np.float32))

    # batched with staggered positions: prefill each row separately
    # into a shared pool via per-row writes
    pool = tfm.init_cache(cfg, 2, 32)
    from repro.serving.continuous import _splice
    toks_last = np.zeros((2, 1), np.int32)
    pos = np.zeros(2, np.int32)
    for i, s in enumerate(seqs):
        row = tfm.init_cache(cfg, 1, 32)
        _, row = tfm.prefill(cfg, params, s[:, :-1], row)
        pool = _splice(pool, row, i)
        toks_last[i, 0] = int(s[0, -1])
        pos[i] = s.shape[1] - 1
    lg, _ = tfm.decode_step(cfg, params, jnp.asarray(toks_last), pool,
                            jnp.asarray(pos))
    for i in range(2):
        np.testing.assert_allclose(np.asarray(lg[i, 0], np.float32),
                                   refs[i], rtol=2e-2, atol=2e-2)


def test_continuous_engine_end_to_end():
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(rid=i,
                       prompt=rng.integers(0, cfg.vocab, 8),
                       max_new=5 + (i % 4))
            for i in range(7)]
    stats = eng.serve(reqs, prompt_len=8)
    assert stats["n_admitted"] == 7
    assert all(r.done for r in reqs)
    assert all(len(r.generated) >= r.max_new for r in reqs)
    # more requests than slots => multiple refill waves, occupancy > 0.5
    assert stats["occupancy"] > 0.5


def _seeded_workload(cfg, n=9, plen=8, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, plen) for _ in range(n)]
    return lambda: [GenRequest(rid=i, prompt=prompts[i],
                               max_new=4 + (i % 4)) for i in range(n)]


def test_fused_loop_parity_with_legacy():
    """The in-graph k-step loop must produce byte-identical greedy
    token sequences vs the legacy per-step Python loop; at k=1 (same
    refill cadence) the summary stats must match too."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    mk = _seeded_workload(cfg)

    eng = ContinuousBatchingEngine(cfg, params, n_slots=3, max_seq=64)
    rl = mk()
    sl = eng.serve(rl, prompt_len=8, legacy=True)
    for k in (1, 4):
        eng_f = ContinuousBatchingEngine(cfg, params, n_slots=3,
                                         max_seq=64, sync_every=k)
        rf = mk()
        sf = eng_f.serve(rf, prompt_len=8)
        assert [r.generated for r in rf] == [r.generated for r in rl], \
            f"greedy tokens diverged at sync_every={k}"
        assert all(r.done for r in rf)
    # k=1: refill cadence identical to legacy -> identical stats
    eng1 = ContinuousBatchingEngine(cfg, params, n_slots=3, max_seq=64,
                                    sync_every=1)
    s1 = eng1.serve(mk(), prompt_len=8)
    for key in ("decode_steps", "occupied_slot_steps", "occupancy",
                "tokens_generated", "n_admitted"):
        assert s1[key] == sl[key], (key, s1[key], sl[key])


def test_decode_window_compiles_once_across_refills():
    """Shape-drift regression: the fused decode window must trace
    exactly once no matter how many refill waves the workload needs."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                   sync_every=4)
    stats = eng.serve(_seeded_workload(cfg, n=7)(), prompt_len=8)
    assert stats["prefill_calls"] >= 3          # several refill waves
    assert eng.decode_compile_count == 1


def test_fused_loop_respects_max_seq():
    """Budgets larger than the pool allow must stop at max_seq-1, like
    the legacy loop does."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    mk = lambda: [GenRequest(rid=0, prompt=np.arange(8) % cfg.vocab,
                             max_new=100)]
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=16)
    rl = mk()
    eng.serve(rl, prompt_len=8, legacy=True)
    eng_f = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=16,
                                     sync_every=4)
    rf = mk()
    eng_f.serve(rf, prompt_len=8)
    assert rf[0].generated == rl[0].generated
    assert rf[0].done


def test_eos_stops_generation_in_both_loops():
    """A request with an eos_id must stop at the first emitted EOS —
    identically in the fused window and the legacy loop."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    mk0 = _seeded_workload(cfg, n=4, seed=5)
    probe = mk0()
    ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64) \
        .serve(probe, prompt_len=8)
    # pick each request's 3rd emitted token as its EOS so every
    # request stops early on a token we KNOW the model emits
    def mk():
        reqs = mk0()
        for r, p in zip(reqs, probe):
            r.max_new = 7
            r.eos_id = p.generated[2]
        return reqs
    eng_l = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64)
    rl = mk()
    eng_l.serve(rl, prompt_len=8, legacy=True)
    eng_f = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                     sync_every=4)
    rf = mk()
    eng_f.serve(rf, prompt_len=8)
    assert [r.generated for r in rf] == [r.generated for r in rl]
    for r in rf:
        assert r.done
        # stopped AT the eos token, well before the max_new budget
        assert r.generated[-1] == r.eos_id
        assert len(r.generated) <= 3


def test_eos_prefill_wave_does_not_drop_queue():
    """If every request of a refill wave hits EOS straight out of
    prefill, the slot must be retried with the next queued request —
    not leave the rest of the queue stranded (legacy regression)."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    mk0 = _seeded_workload(cfg, n=3, seed=9)
    probe = mk0()
    ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64) \
        .serve(probe, prompt_len=8)

    def mk():
        reqs = mk0()
        # first two die at their prefill token; the third runs free
        for r, p in zip(reqs[:2], probe[:2]):
            r.eos_id = p.generated[0]
        return reqs

    rl = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64) \
        .serve(rl, prompt_len=8, legacy=True)
    rf = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                             sync_every=4).serve(rf, prompt_len=8)
    assert all(r.done for r in rl) and all(r.done for r in rf)
    assert [r.generated for r in rf] == [r.generated for r in rl]
    assert len(rl[0].generated) == 1          # stopped at prefill
    assert len(rl[2].generated) > 1           # still served


def test_single_slot_pool_parity():
    """n_slots == 1: the batch-1 pool is shape-identical to the row
    cache, which the axis detector cannot see — both loops must still
    serve correctly (legacy assigns the row, fused scatters)."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    mk = _seeded_workload(cfg, n=3, seed=11)
    rl = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=1, max_seq=64) \
        .serve(rl, prompt_len=8, legacy=True)
    rf = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=1, max_seq=64,
                             sync_every=4).serve(rf, prompt_len=8)
    assert all(r.done for r in rl) and all(r.done for r in rf)
    assert [r.generated for r in rf] == [r.generated for r in rl]
    # against isolated lockstep generation: slot pool of one must
    # equal a plain batch-1 prefill+decode
    r0 = mk()[0]
    cache = tfm.init_cache(cfg, 1, 64)
    p = jnp.asarray(np.asarray(r0.prompt[:8], np.int32)[None])
    logits, cache = tfm.prefill(cfg, params, p, cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = 8
    while len(toks) < len(rl[0].generated):
        logits, cache = tfm.decode_step(
            cfg, params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            pos)
        toks.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    assert toks == rl[0].generated


def test_admission_uses_request_arrival_times():
    """The controller must be driven by the workload's arrival clock
    (``arrival_t``), not a fake fixed-increment one."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    ctrl = AdmissionController(
        threshold=DecayingThreshold(0.2, 0.2, 1.0))
    for v in np.linspace(0, 1, 32):
        ctrl.cost.observe(v, 1.0, 0.0)
    ctrl.meter.record(1.0)
    rng = np.random.default_rng(3)
    arrivals = [0.0, 1.5, 2.25, 7.75]
    reqs = [GenRequest(rid=i, prompt=rng.integers(0, cfg.vocab, 8),
                       max_new=3, arrival_t=arrivals[i])
            for i in range(4)]
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                   controller=ctrl, sync_every=2)
    eng.serve(reqs, prompt_len=8)
    assert [d.t for d in ctrl.history] == arrivals


# ---------------------------------------------------------------------------
# slot writes
# ---------------------------------------------------------------------------

def test_leaf_batch_axis_raises_on_unknown_layouts():
    with pytest.raises(ValueError):
        _leaf_batch_axis((4, 4), (5, 5))        # two differing axes
    with pytest.raises(ValueError):
        _leaf_batch_axis((4, 4), (4, 4, 4))     # rank change
    assert _leaf_batch_axis((2, 7), (3, 7)) == 0
    assert _leaf_batch_axis((5, 5), (5, 5)) == -1


def test_slot_write_raises_on_mismatched_leaf():
    """A cache row that doesn't fit the pool at the derived batch axis
    must raise, not silently drop the prefilled row."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    axes = cache_batch_axes(cfg, 32)
    pool = tfm.init_cache(cfg, 4, 32)
    bad_rows = jax.tree_util.tree_map(
        lambda x: (x[..., :-1] if hasattr(x, "ndim") and x.ndim >= 4
                   else x),
        tfm.init_cache(cfg, 2, 32))
    with pytest.raises(ValueError, match="refusing to drop"):
        slot_write(pool, bad_rows, jnp.array([0, 1]), axes)


def test_legacy_splice_raises_on_ambiguous_leaf():
    pool = {"x": jnp.zeros((4, 5))}
    row = {"x": jnp.zeros((1, 3))}              # two differing axes
    with pytest.raises(ValueError):
        _splice(pool, row, 0)


# ---------------------------------------------------------------------------
# paged KV pool
# ---------------------------------------------------------------------------

def _smoke_cfg():
    return get_smoke_config("stablelm-3b").replace(remat=False)


def _paged(cfg, **kw):
    return cfg.replace(kv_block_size=8, **kw)


def test_paged_parity_with_contiguous_across_refills():
    """The paged pool must produce byte-identical greedy tokens to the
    contiguous parity oracle over multiple refill waves."""
    cfg = _smoke_cfg()
    params = tfm.init_lm(cfg, KEY)
    mk = _seeded_workload(cfg, n=9)
    rc = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=3, max_seq=64,
                             sync_every=4).serve(rc, prompt_len=8)
    rp = mk()
    stats = ContinuousBatchingEngine(_paged(cfg), params, n_slots=3,
                                     max_seq=64, sync_every=4) \
        .serve(rp, prompt_len=8)
    assert [r.generated for r in rp] == [r.generated for r in rc]
    assert all(r.done for r in rp)
    assert stats["mode"] == "paged"
    assert stats["prefill_calls"] >= 3           # several refill waves


def test_paged_native_kernel_token_parity_end_to_end():
    """The table-native paged flash-decode kernel (attn_impl="pallas",
    interpret mode on CPU) must produce byte-identical greedy tokens
    to the default dispatch through a full DecodeSession serve —
    refills, block tables, trash-block masking and all."""
    cfg = _paged(_smoke_cfg())
    params = tfm.init_lm(cfg, KEY)
    mk = _seeded_workload(cfg, n=4)
    r_ref = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=32,
                             sync_every=2).serve(r_ref, prompt_len=8)
    r_nat = mk()
    stats = ContinuousBatchingEngine(
        cfg.replace(attn_impl="pallas"), params, n_slots=2, max_seq=32,
        sync_every=2).serve(r_nat, prompt_len=8)
    assert [r.generated for r in r_nat] == [r.generated for r in r_ref]
    assert all(r.done for r in r_nat)
    assert stats["mode"] == "paged"


def test_paged_parity_with_eos_waves():
    """EOS early-stops — mid-decode and straight out of prefill — must
    free blocks and keep token parity with the contiguous oracle."""
    cfg = _smoke_cfg()
    params = tfm.init_lm(cfg, KEY)
    mk0 = _seeded_workload(cfg, n=4, seed=5)
    probe = mk0()
    ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64) \
        .serve(probe, prompt_len=8)

    def mk():
        reqs = mk0()
        for r, p in zip(reqs, probe):
            r.max_new = 7
        reqs[0].eos_id = probe[0].generated[0]   # dies at prefill
        reqs[1].eos_id = probe[1].generated[2]   # dies mid-decode
        return reqs

    rc = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                             sync_every=4).serve(rc, prompt_len=8)
    rp = mk()
    eng = ContinuousBatchingEngine(_paged(cfg), params, n_slots=2,
                                   max_seq=64, sync_every=4)
    stats = eng.serve(rp, prompt_len=8)
    assert [r.generated for r in rp] == [r.generated for r in rc]
    assert all(r.done for r in rp)
    assert stats["blocks_allocated"] == stats["blocks_freed"]


def test_paged_block_accounting_across_windows():
    """Every block is free or owned by exactly one slot after every
    window; the ledger balances when the session drains."""
    cfg = _paged(_smoke_cfg())
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                   sync_every=2)
    sess = eng.start_session(8)
    for r in _seeded_workload(cfg, n=7)():
        sess.push(r)
    allocatable = eng.pool_blocks - 1
    windows = 0
    while not sess.idle:
        sess.advance()
        windows += 1
        owned = [b for bl in sess._slot_blocks.values() for b in bl]
        assert len(owned) == len(set(owned))          # unique owners
        assert 0 not in owned                         # trash reserved
        assert set(owned).isdisjoint(sess._free_blocks)
        assert len(owned) + len(sess._free_blocks) == allocatable
    assert windows > 2
    assert sess.blocks_allocated == sess.blocks_freed > 0
    assert len(sess._free_blocks) == allocatable
    assert sess.peak_blocks_in_use <= allocatable


def test_paged_pool_exhaustion_queue_waits():
    """A pool too small for all slots serialises admission: requests
    WAIT in the queue (never dropped) and tokens stay byte-identical
    to the contiguous oracle."""
    cfg = _smoke_cfg()
    params = tfm.init_lm(cfg, KEY)
    mk = _seeded_workload(cfg, n=5, seed=3)
    rc = mk()
    ContinuousBatchingEngine(cfg, params, n_slots=3, max_seq=64,
                             sync_every=2).serve(rc, prompt_len=8)
    # each request needs 2 blocks (8 prompt + <8 new rows @ bs=8);
    # 3 allocatable blocks fit only ONE request at a time
    pcfg = _paged(cfg, kv_pool_blocks=4)
    eng = ContinuousBatchingEngine(pcfg, params, n_slots=3, max_seq=64,
                                   sync_every=2)
    sess = eng.start_session(8)
    rp = mk()
    for r in rp:
        sess.push(r)
    while not sess.idle:
        sess.advance()
        assert sess.n_active <= 1        # pool admits one at a time
    assert all(r.done for r in rp)       # queue waited, nothing lost
    assert [r.generated for r in rp] == [r.generated for r in rc]


def test_paged_request_too_big_raises():
    """A request whose budget exceeds the WHOLE pool can never be
    served — that is a config error, not a queue wait."""
    cfg = _smoke_cfg()
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(_paged(cfg, kv_pool_blocks=2),
                                   params, n_slots=2, max_seq=64)
    reqs = [GenRequest(rid=0, prompt=np.arange(8) % cfg.vocab,
                       max_new=8)]
    with pytest.raises(ValueError, match="never be served"):
        eng.serve(reqs, prompt_len=8)


def test_paged_long_prompt_does_not_inflate_earlier_budget():
    """A long prompt deeper in the queue must not re-pad an earlier
    short request past the pool: the short one serves in its own wave
    at its own padding, the long one follows when blocks free up."""
    cfg = _paged(_smoke_cfg(), kv_pool_blocks=13)   # 12 allocatable
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=128,
                                   sync_every=2)
    sess = eng.start_session(None)                  # dynamic plen
    rng = np.random.default_rng(0)
    short = GenRequest(rid=0, prompt=rng.integers(0, cfg.vocab, 8),
                       max_new=4)                   # solo: 2 blocks
    long_ = GenRequest(rid=1, prompt=rng.integers(0, cfg.vocab, 40),
                       max_new=4)                   # solo: 9 blocks
    sess.push(short)
    sess.push(long_)
    # co-padding both to the long prompt's bucket would cost 9 blocks
    # EACH (18 > 12) — the wave must instead split, not raise
    while not sess.idle:
        sess.advance()
    assert short.done and long_.done
    assert len(short.generated) >= 4 and len(long_.generated) >= 4
    assert sess.blocks_allocated == sess.blocks_freed
    assert len(sess._free_blocks) == 12


def test_paged_unservable_request_raise_leaves_state_clean():
    """The can-never-be-served error must fire BEFORE any block is
    popped: no leaked blocks, no half-admitted wave, queue intact."""
    cfg = _paged(_smoke_cfg(), kv_pool_blocks=4)    # 3 allocatable
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                   sync_every=2)
    sess = eng.start_session(8)
    rng = np.random.default_rng(1)
    ok = GenRequest(rid=0, prompt=rng.integers(0, cfg.vocab, 8),
                    max_new=4)                      # needs 2 blocks
    too_big = GenRequest(rid=1, prompt=rng.integers(0, cfg.vocab, 8),
                         max_new=60)                # needs > 3 blocks
    sess.push(ok)
    sess.push(too_big)
    with pytest.raises(ValueError, match="never be served"):
        sess.advance()
    assert len(sess._free_blocks) == 3              # nothing stranded
    assert sess._slot_blocks == {}
    assert sess.n_queued == 2                       # queue untouched


def test_paged_decode_window_compiles_once():
    """Shape-drift regression for the paged scan: one trace no matter
    how many refill waves (block tables ride the cache pytree with a
    static shape)."""
    cfg = _paged(_smoke_cfg())
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                   sync_every=4)
    stats = eng.serve(_seeded_workload(cfg, n=7)(), prompt_len=8)
    assert stats["prefill_calls"] >= 3
    assert eng.decode_compile_count == 1


def test_paged_legacy_loop_refuses():
    cfg = _paged(_smoke_cfg())
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64)
    with pytest.raises(ValueError, match="contiguous"):
        eng.serve(_seeded_workload(cfg, n=2)(), prompt_len=8,
                  legacy=True)


def test_paged_prefill_into_pool_raises():
    """tfm.prefill must refuse a paged pool — prefill goes through a
    contiguous row cache + block scatter, never table indirection."""
    cfg = _paged(_smoke_cfg())
    params = tfm.init_lm(cfg, KEY)
    pool = tfm.init_cache(cfg, 2, 32)
    toks = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="paged pool"):
        tfm.prefill(cfg, params, toks, pool)


def test_paged_rejects_unsupported_layouts():
    """Windowed / recurrent stacks keep constant-size state per slot —
    the paged pool refuses them instead of silently mislaying rows."""
    cfg = _paged(_smoke_cfg(), window=16)       # -> local_attn kinds
    with pytest.raises(ValueError, match="paged KV pool"):
        tfm.init_cache(cfg, 2, 64)


def test_paged_misconfigurations_rejected():
    """Half-configured paging must be loud: a pool size without a
    block size would silently serve contiguous, and forcing
    layout='paged' on a contiguous config has no geometry."""
    with pytest.raises(ValueError, match="kv_block_size"):
        _smoke_cfg().replace(kv_pool_blocks=8)
    with pytest.raises(ValueError, match="kv_block_size"):
        tfm.init_cache(_smoke_cfg(), 2, 64, layout="paged")


def test_splice_batch1_pool_raises():
    """The n_slots == 1 caveat is now a hard error at the call
    boundary: a batch-1 pool has no identifiable batch axis."""
    cfg = _smoke_cfg()
    pool = tfm.init_cache(cfg, 1, 32)
    row = tfm.init_cache(cfg, 1, 32)
    with pytest.raises(ValueError, match="batch-1"):
        _splice(pool, row, 0)


def test_paged_decode_attend_kernel_path_matches_jnp():
    """The block-table kernel shim (kops dispatch) must agree with the
    pure-jnp gather path on a scattered block layout."""
    from repro.models import attention as attn
    B, K, H, hd, bs, mb = 2, 2, 4, 16, 8, 3
    C = mb * bs
    nb = 1 + B * mb
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    cache = attn.init_paged_kv_cache(B, C, K, hd, n_blocks=nb,
                                     block_size=bs, dtype=jnp.float32)
    rng = np.random.default_rng(2)
    perm = rng.permutation(np.arange(1, nb)).reshape(B, mb)
    table = jnp.asarray(perm, jnp.int32)
    k_pool = jax.random.normal(ks[0], cache.k.shape)
    v_pool = jax.random.normal(ks[1], cache.v.shape)
    pos = jnp.broadcast_to(jnp.arange(C), (B, C))
    pos = pos.at[:, C - 5:].set(-1)              # unwritten tail
    cache = cache._replace(k=k_pool, v=v_pool, pos=pos)
    q = jax.random.normal(ks[2], (B, 1, H, hd))
    cur = jnp.array([C - 6, C - 8], jnp.int32)
    o_jnp = attn.paged_decode_attend(q, cache, table, pos=cur)
    o_ker = attn.paged_decode_attend_kernel(q, cache, table, pos=cur,
                                            impl="ref")
    np.testing.assert_allclose(np.asarray(o_jnp, np.float32),
                               np.asarray(o_ker, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_continuous_engine_with_controller():
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    ctrl = AdmissionController(
        threshold=DecayingThreshold(0.2, 0.2, 1.0))
    for v in np.linspace(0, 1, 32):
        ctrl.cost.observe(v, 1.0, 0.0)
    ctrl.meter.record(1.0)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                   controller=ctrl)
    rng = np.random.default_rng(1)
    reqs = [GenRequest(rid=i, prompt=rng.integers(0, cfg.vocab, 8),
                       max_new=4, entropy_hint=float(i % 10) / 10)
            for i in range(10)]
    stats = eng.serve(reqs, prompt_len=8)
    assert 0 < stats["n_admitted"] < 10      # controller pruned some
    skipped = [r for r in reqs if not r.admitted]
    assert all(r.done and not r.generated for r in skipped)

# ---------------------------------------------------------------------------
# SlotClock — direct unit coverage (previously only exercised through
# SimContinuousEngine / the fleet layer)
# ---------------------------------------------------------------------------

def test_slot_clock_reserve_picks_earliest_free_slot():
    from repro.serving.continuous import SlotClock
    clk = SlotClock(n_slots=2)
    s0, st0, f0 = clk.reserve(0.0, 1.0)
    s1, st1, f1 = clk.reserve(0.0, 0.25)
    assert s0 != s1 and st0 == st1 == 0.0
    # the slot freeing at 0.25 (not the 1.0 one) takes the next job,
    # and service starts at that slot's horizon, not at now
    s2, st2, f2 = clk.reserve(0.0, 0.5)
    assert s2 == s1
    assert st2 == pytest.approx(0.25) and f2 == pytest.approx(0.75)
    # start never precedes now on an already-free slot
    s3, st3, f3 = clk.reserve(2.0, 0.5)
    assert st3 == 2.0 and f3 == 2.5


def test_slot_clock_pressure_monotone_and_zero_when_free():
    from repro.serving.continuous import SlotClock
    clk = SlotClock(n_slots=2)
    clk.reserve(0.0, 1.0)
    clk.reserve(0.0, 2.0)
    ps = [clk.pressure(t) for t in (0.0, 0.5, 1.0, 1.5, 2.0, 3.0)]
    assert all(a >= b for a, b in zip(ps, ps[1:]))   # non-increasing
    # pressure is the wait for a NEW arrival: the earliest-free slot
    assert ps[0] == pytest.approx(1.0)
    assert clk.pressure(1.0) == 0.0                  # a slot just freed
    # polling is side-effect-free
    assert clk.pressure(0.0) == clk.pressure(0.0) == pytest.approx(1.0)


def test_slot_clock_busy_counts_and_reset_clears():
    from repro.serving.continuous import SlotClock
    clk = SlotClock(n_slots=3)
    clk.reserve(0.0, 1.0)
    clk.reserve(0.0, 2.0)
    assert clk.busy(0.5) == 2
    assert clk.busy(1.5) == 1
    assert clk.busy(2.5) == 0
    clk.reset()
    assert clk.busy(0.0) == 0
    assert clk.pressure(0.0) == 0.0
    assert clk.free_at == [0.0] * 3
