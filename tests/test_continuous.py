"""Continuous batching: per-row decode positions + slot splicing must
reproduce exactly what isolated lockstep generation produces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import AdmissionController, DecayingThreshold
from repro.models import transformer as tfm
from repro.serving.continuous import (ContinuousBatchingEngine,
                                      GenRequest)

KEY = jax.random.PRNGKey(0)


def test_per_row_positions_match_lockstep():
    """decode_step with a pos VECTOR must agree with scalar pos when
    all rows share the position (regression for the vector path)."""
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (3, 9), 0, cfg.vocab)
    c1 = tfm.init_cache(cfg, 3, 32)
    _, c1 = tfm.prefill(cfg, params, toks[:, :8], c1)
    c2 = jax.tree_util.tree_map(lambda x: x, c1)
    lg_s, _ = tfm.decode_step(cfg, params, toks[:, 8:9], c1, 8)
    lg_v, _ = tfm.decode_step(cfg, params, toks[:, 8:9], c2,
                              jnp.array([8, 8, 8]))
    np.testing.assert_allclose(
        np.asarray(lg_s, np.float32), np.asarray(lg_v, np.float32),
        rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["stablelm-3b", "minicpm3-4b"])
def test_per_row_positions_staggered(arch):
    """Rows at DIFFERENT positions: each must match its own isolated
    batch-1 decode."""
    cfg = get_smoke_config(arch).replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    seqs = [jax.random.randint(jax.random.PRNGKey(i), (1, 6 + 2 * i),
                               0, cfg.vocab) for i in range(2)]
    # isolated references
    refs = []
    for s in seqs:
        c = tfm.init_cache(cfg, 1, 32)
        _, c = tfm.prefill(cfg, params, s[:, :-1], c)
        lg, _ = tfm.decode_step(cfg, params, s[:, -1:], c,
                                s.shape[1] - 1)
        refs.append(np.asarray(lg[0, 0], np.float32))

    # batched with staggered positions: prefill each row separately
    # into a shared pool via per-row writes
    pool = tfm.init_cache(cfg, 2, 32)
    from repro.serving.continuous import _splice
    toks_last = np.zeros((2, 1), np.int32)
    pos = np.zeros(2, np.int32)
    for i, s in enumerate(seqs):
        row = tfm.init_cache(cfg, 1, 32)
        _, row = tfm.prefill(cfg, params, s[:, :-1], row)
        pool = _splice(pool, row, i)
        toks_last[i, 0] = int(s[0, -1])
        pos[i] = s.shape[1] - 1
    lg, _ = tfm.decode_step(cfg, params, jnp.asarray(toks_last), pool,
                            jnp.asarray(pos))
    for i in range(2):
        np.testing.assert_allclose(np.asarray(lg[i, 0], np.float32),
                                   refs[i], rtol=2e-2, atol=2e-2)


def test_continuous_engine_end_to_end():
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(rid=i,
                       prompt=rng.integers(0, cfg.vocab, 8),
                       max_new=5 + (i % 4))
            for i in range(7)]
    stats = eng.serve(reqs, prompt_len=8)
    assert stats["n_admitted"] == 7
    assert all(r.done for r in reqs)
    assert all(len(r.generated) >= r.max_new for r in reqs)
    # more requests than slots => multiple refill waves, occupancy > 0.5
    assert stats["occupancy"] > 0.5


def test_continuous_engine_with_controller():
    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, KEY)
    ctrl = AdmissionController(
        threshold=DecayingThreshold(0.2, 0.2, 1.0))
    for v in np.linspace(0, 1, 32):
        ctrl.cost.observe(v, 1.0, 0.0)
    ctrl.meter.record(1.0)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_seq=64,
                                   controller=ctrl)
    rng = np.random.default_rng(1)
    reqs = [GenRequest(rid=i, prompt=rng.integers(0, cfg.vocab, 8),
                       max_new=4, entropy_hint=float(i % 10) / 10)
            for i in range(10)]
    stats = eng.serve(reqs, prompt_len=8)
    assert 0 < stats["n_admitted"] < 10      # controller pruned some
    skipped = [r for r in reqs if not r.admitted]
    assert all(r.done and not r.generated for r in skipped)