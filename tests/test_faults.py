"""Failure model (repro.faults): fault plans and injection, the health
state machine, deadlines, bounded retry/failover, brownout, and the
chaos recovery invariants — exactly-once, never-hang, deterministic."""
import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import AdmissionController, DecayingThreshold
from repro.faults import (BrownoutController, FAILED, FAULT_KINDS,
                          FaultEvent, FaultInjector, FaultPlan, HEALTHY,
                          HealthState, RECOVERING, RetryPolicy,
                          CHAOS_SCENARIOS, make_chaos, with_deadlines)
from repro.fleet import (EnergyAwareRouter, FleetSimulator,
                         build_sim_fleet, make_scenario,
                         make_sim_replica, with_deadline)
from repro.serving.api import PATH_REJECT, InferRequest, request_expiry

KINDS3 = ("direct", "dynamic-batch", "gated-in-graph")


def _chaos_fleet(ch, **kw):
    pool = build_sim_fleet(ch.scenario.oracle, kinds=KINDS3)
    sim = FleetSimulator(pool, EnergyAwareRouter(),
                         injector=FaultInjector(ch.plan),
                         retry_policy=RetryPolicy(),
                         brownout=BrownoutController(), **kw)
    return sim, pool


# ---------------------------------------------------------------------------
# fault plans: schedule, seeding, injection
# ---------------------------------------------------------------------------

def test_fault_plan_scripted_sorts_and_validates():
    plan = FaultPlan.scripted([
        FaultEvent(t=2.0, kind="crash", target="b"),
        FaultEvent(t=1.0, kind="degrade", target="a", magnitude=2.5),
    ])
    assert [e.t for e in plan.events] == [1.0, 2.0]
    with pytest.raises(ValueError):
        FaultEvent(t=-1.0, kind="crash")
    with pytest.raises(ValueError):
        FaultEvent(t=0.0, kind="crash", duration_s=-0.1)


def test_unknown_fault_kind_suggests_nearest():
    with pytest.raises(ValueError, match="did you mean 'crash'"):
        FaultEvent(t=0.0, kind="crsh")
    with pytest.raises(ValueError, match="link-flap"):
        FaultEvent(t=0.0, kind="link-flop")


def test_seeded_plan_identical_per_seed():
    kw = dict(targets=["a", "b", "c"], horizon_s=10.0, n_events=6)
    p1 = FaultPlan.seeded(42, **kw)
    p2 = FaultPlan.seeded(42, **kw)
    # byte-identical schedule and signature
    assert p1.to_json() == p2.to_json()
    assert p1.signature() == p2.signature()
    assert len(p1.events) == 6
    assert all(e.kind in FAULT_KINDS for e in p1.events)
    assert all(0.0 <= e.t <= 10.0 for e in p1.events)
    p3 = FaultPlan.seeded(43, **kw)
    assert p3.to_json() != p1.to_json()


def test_injector_drains_in_order():
    plan = FaultPlan.scripted([
        FaultEvent(t=1.0, kind="crash", target="a"),
        FaultEvent(t=3.0, kind="degrade", target="b"),
    ])
    inj = FaultInjector(plan)
    assert inj.next_t() == 1.0
    assert [e.t for e in inj.pop_due(2.0)] == [1.0]
    assert not inj.exhausted
    assert [e.t for e in inj.pop_due(5.0)] == [3.0]
    assert inj.exhausted
    inj.reset()
    assert inj.next_t() == 1.0


# ---------------------------------------------------------------------------
# health state machine / retry policy / brownout
# ---------------------------------------------------------------------------

def test_health_state_machine_transitions():
    h = HealthState()
    assert h.status == HEALTHY and h.routable
    h.fail(1.0, 0.5)
    assert h.status == FAILED and not h.routable
    assert h.n_crashes == 1
    # degrading a dead node is a no-op
    h.degrade(1.1, 3.0, 1.0)
    assert h.status == FAILED
    h.recover(1.5, recovering_s=0.25)
    assert h.status == RECOVERING and h.routable
    h.heal()
    assert h.status == HEALTHY and h.slow_factor == 1.0
    h.degrade(2.0, 2.0, 1.0)
    h.degrade(2.1, 3.0, 0.5)          # overlapping episodes max-merge
    assert h.slow_factor == 3.0
    h.recover(3.0)                    # no warm-up -> straight to healthy
    assert h.status == HEALTHY


def test_retry_policy_backoff_bounded():
    p = RetryPolicy(max_retries=3, backoff_base_s=0.1,
                    backoff_mult=2.0, backoff_max_s=0.3)
    assert [p.allows(a) for a in (1, 2, 3, 4)] == [True, True, True,
                                                   False]
    assert p.delay(1) == pytest.approx(0.1)
    assert p.delay(2) == pytest.approx(0.2)
    assert p.delay(3) == pytest.approx(0.3)   # capped
    assert p.delay(9) == pytest.approx(0.3)


def test_brownout_pressure_decays_and_recovers():
    b = BrownoutController(half_life_s=1.0, sensitivity=1.0,
                           min_scale=0.4)
    assert b.scale(0.0) == 1.0
    b.record(0.0, 4.0)
    s0 = b.scale(0.0)
    assert 0.4 <= s0 < 1.0
    assert b.scale(3.0) > s0          # pressure decays with time
    assert b.scale(30.0) == pytest.approx(1.0, abs=1e-2)
    assert b.min_scale_seen == s0


def test_brownout_tightens_tau_via_scale():
    ctrl = AdmissionController(
        threshold=DecayingThreshold(tau0=1.0, tau_inf=0.5, k=0.5))
    tau_open = ctrl.peek(0.0)[0]
    ctrl.tau_scale = 0.5
    assert ctrl.peek(0.0)[0] == pytest.approx(0.5 * tau_open)
    # a 'ge' rule keeps the same admission basin by dividing
    ctrl_ge = AdmissionController(
        threshold=DecayingThreshold(tau0=1.0, tau_inf=0.5, k=0.5),
        rule="ge")
    tau_ge = ctrl_ge.peek(0.0)[0]
    ctrl_ge.tau_scale = 0.5
    assert ctrl_ge.peek(0.0)[0] == pytest.approx(tau_ge / 0.5)


# ---------------------------------------------------------------------------
# deadlines: counted once, never executed
# ---------------------------------------------------------------------------

def test_request_expiry_reads_deadline_and_override():
    r = InferRequest(rid=0, arrival_s=1.0)
    assert request_expiry(r) == float("inf")
    r2 = InferRequest(rid=1, arrival_s=1.0, deadline_s=0.5)
    assert request_expiry(r2) == pytest.approx(1.5)
    r3 = InferRequest(rid=2, arrival_s=9.0, deadline_s=0.5,
                      metadata={"expires_at": 1.5})
    assert request_expiry(r3) == pytest.approx(1.5)


def test_with_deadline_clones_trace():
    sc = make_scenario("steady", 20, seed=0)
    dl = with_deadline(sc, 0.8)
    assert all(r.deadline_s == 0.8 for r in dl.requests)
    assert all(r.deadline_s is None for r in sc.requests)  # untouched
    assert [r.rid for r in dl.requests] == [r.rid for r in sc.requests]
    cleared = with_deadline(dl, None)
    assert all(r.deadline_s is None for r in cleared.requests)


def test_expired_request_rejected_once_never_executed():
    sc = make_scenario("steady", 40, seed=1)
    dl = with_deadline(sc, 0.0)       # expired on arrival
    pool = build_sim_fleet(sc.oracle, kinds=KINDS3)
    rep = FleetSimulator(pool, EnergyAwareRouter()).run(dl.requests)
    assert len(rep.responses) == 40
    assert sorted(r.rid for r in rep.responses) == list(range(40))
    assert all(r.path == PATH_REJECT for r in rep.responses)
    assert all(r.telemetry["reason"] == "deadline-expired"
               for r in rep.responses)
    assert rep.summary["n_expired"] == 40
    assert rep.summary["n_served"] == 0
    # the engines never executed anything
    assert all(r.server.log.n == 0 for r in pool.replicas)


def test_queued_request_shed_at_expiry():
    sc = make_scenario("steady", 10, seed=2)
    r = make_sim_replica("b-0", "dynamic-batch", sc.oracle,
                         queue_window_s=10.0)   # park work in the window
    r.start()
    req = InferRequest(rid=0, arrival_s=0.0, deadline_s=0.1,
                       label=int(sc.oracle.labels[0]),
                       entropy_hint=0.2)
    r.push(req)
    shed = r.server.shed_expired(5.0)
    assert [x.rid for x in shed] == [0]
    out = r.finish(6.0)
    mine = [x for x in out if x.rid == 0]
    assert len(mine) == 1             # exactly once
    assert mine[0].path == PATH_REJECT


# ---------------------------------------------------------------------------
# failover: crash claw-back, retry budgets, all-stopped pools
# ---------------------------------------------------------------------------

def test_crash_now_claws_back_inflight_and_wastes_joules():
    sc = make_scenario("steady", 10, seed=3)
    r = make_sim_replica("d-0", "direct", sc.oracle)
    r.start()
    req = sc.requests[0]
    done = [x for x in r.push(req) if x.rid == req.rid]
    assert done and done[0].t_finish > req.arrival_s
    mid = (req.arrival_s + done[0].t_finish) / 2
    report = r.crash(mid, duration_s=0.5)
    assert req.rid in report.lost_rids
    assert report.wasted_j > 0.0      # partially-burned joules booked
    assert r.wasted_j == pytest.approx(report.wasted_j)
    assert r.server.log.n == 0        # clawed out of the request log
    assert not r.routable and not r.revivable
    r.recover(mid + 1.0)
    assert r.routable


def test_all_stopped_pool_rejects_with_reason_not_crash():
    """Satellite regression: zero routable replicas must never raise —
    every request resolves as a bounded-retry rejection and the clock
    keeps advancing."""
    sc = make_scenario("steady", 30, seed=4)
    plan = FaultPlan.scripted([
        FaultEvent(t=0.0, kind="crash", target=f"{k}-{i}",
                   duration_s=1000.0)
        for i, k in enumerate(KINDS3)])
    pool = build_sim_fleet(sc.oracle, kinds=KINDS3)
    sim = FleetSimulator(pool, EnergyAwareRouter(),
                         injector=FaultInjector(plan),
                         retry_policy=RetryPolicy(max_retries=2))
    rep = sim.run(sc.requests)        # must not raise
    assert len(rep.responses) == 30
    assert sorted(r.rid for r in rep.responses) == list(range(30))
    assert all(r.path == PATH_REJECT for r in rep.responses)
    assert all(r.telemetry["reason"]
               == "retry-budget:no-routable-replica"
               for r in rep.responses)
    assert rep.summary["span_s"] > 0


def test_unmatched_kind_rejects_instead_of_hanging():
    sc = make_scenario("steady", 4, seed=5)
    gen = [InferRequest(rid=99, arrival_s=0.0, kind="generate",
                        payload=np.zeros(4, np.int32))]
    pool = build_sim_fleet(sc.oracle, kinds=KINDS3)
    rep = FleetSimulator(pool, EnergyAwareRouter(),
                         retry_policy=RetryPolicy(max_retries=1)).run(
        sc.requests + gen)
    mine = [r for r in rep.responses if r.rid == 99]
    assert len(mine) == 1
    assert mine[0].path == PATH_REJECT
    assert mine[0].telemetry["reason"].startswith("retry-budget:")
    # the classifier traffic still served normally
    assert rep.summary["n_served"] == 4


def test_autoscaler_revives_parked_but_never_failed():
    sc = make_scenario("steady", 10, seed=6)
    pool = build_sim_fleet(sc.oracle, kinds=KINDS3).start()
    drained = pool.replicas[0]
    drained.drain(0.0)
    crashed = pool.replicas[1]
    crashed.crash(0.0, duration_s=10.0)
    assert drained.revivable
    assert not crashed.revivable
    from repro.fleet import Autoscaler
    sca = Autoscaler(hi_pressure_s=0.0, cooldown_s=0.0)
    sca._press = 1.0                  # force the revive branch
    acts = sca.observe(1.0, pool)
    assert acts == [("revive", drained.name)]
    assert crashed.state != "active" and not crashed.routable


def test_by_name_suggests_nearest_replica():
    sc = make_scenario("steady", 4, seed=7)
    pool = build_sim_fleet(sc.oracle, kinds=KINDS3)
    with pytest.raises(KeyError, match="did you mean 'direct-0'"):
        pool.by_name("direct0")


# ---------------------------------------------------------------------------
# chaos scenarios: exactly-once under faults, brownout, determinism
# ---------------------------------------------------------------------------

def test_chaos_registry_and_suggestion():
    assert set(CHAOS_SCENARIOS) >= {"crash-storm", "link-flap",
                                    "crash-and-flap", "seeded-storm"}
    with pytest.raises(ValueError, match="did you mean 'crash-storm'"):
        make_chaos("crash-strom", 10)


def test_crash_and_flap_serves_exactly_once():
    """The acceptance story: a mid-scenario crash plus a link flap —
    >= 95% of requests served in-deadline, each rid exactly once,
    every stranded request retried or rejected-with-reason."""
    ch = make_chaos("crash-and-flap", 400, seed=0)
    sim, pool = _chaos_fleet(ch)
    rep = sim.run(ch.requests())
    rids = [r.rid for r in rep.responses]
    assert sorted(rids) == list(range(400))          # nothing hangs
    assert len(set(rids)) == len(rids)               # exactly once
    assert rep.summary["served_frac"] >= 0.95
    assert rep.summary["n_failures"] == 2
    assert rep.summary["n_retries"] > 0
    rejected = [r for r in rep.responses if r.path == PATH_REJECT]
    assert all(r.telemetry.get("reason") for r in rejected)
    # sustained failure pressure tightened tau(t)
    assert rep.summary["brownout_min_scale"] < 1.0


def test_chaos_run_deterministic_rows():
    """Satellite (c): identical seeds -> identical BENCH rows."""
    import benchmarks.chaos_recovery as cr
    r1 = cr._run_one("crash-and-flap", 150, 0)
    r2 = cr._run_one("crash-and-flap", 150, 0)
    assert json.dumps(r1, sort_keys=True) == json.dumps(r2,
                                                        sort_keys=True)
    p1 = make_chaos("seeded-storm", 50, seed=7).plan
    p2 = make_chaos("seeded-storm", 50, seed=7).plan
    assert p1.to_json() == p2.to_json()


def test_with_deadlines_stamps_chaos_trace():
    ch = make_chaos("crash-storm", 20, seed=0)
    reqs = ch.requests()
    assert all(r.deadline_s == ch.deadline_s for r in reqs)
    again = with_deadlines(ch.scenario, 9.0)
    assert all(r.deadline_s == 9.0 for r in again.requests)


# ---------------------------------------------------------------------------
# disagg failure model: link flaps, decode crashes, retransmission
# ---------------------------------------------------------------------------

def test_transfer_flap_drops_inflight_and_stalls_link():
    from types import SimpleNamespace

    from repro.disagg import TransferQueue
    tq = TransferQueue(gbps=1.0, base_latency_s=0.1)
    pr = SimpleNamespace(kv_bytes=1000)
    t1 = tq.send(pr, 0.0, dst="decode-0")
    t2 = tq.send(pr, 0.0, dst="decode-1")
    assert t2.arrive_t > t1.arrive_t          # serialised FIFO link
    lost = tq.flap(t1.arrive_t, duration_s=2.0)
    assert [t.dst for t in lost] == ["decode-1"]
    assert tq.n_dropped == 1
    assert tq.outage_until == pytest.approx(t1.arrive_t + 2.0)
    # nothing moves during the outage: the next send starts after it
    t3 = tq.send(pr, t1.arrive_t, dst="decode-0")
    assert t3.start_t >= tq.outage_until


def test_transfer_drop_to_and_collapse():
    from types import SimpleNamespace

    from repro.disagg import TransferQueue
    tq = TransferQueue(gbps=1.0, base_latency_s=0.1)
    pr = SimpleNamespace(kv_bytes=1000)
    tq.send(pr, 0.0, dst="decode-0")
    tq.send(pr, 0.0, dst="decode-1")
    lost = tq.drop_to("decode-1")
    assert [t.dst for t in lost] == ["decode-1"]
    assert tq.deliver(10.0)                   # survivor still lands
    fast = tq.send(pr, 20.0, dst="decode-0")
    tq.collapse(30.0, duration_s=5.0, factor=4.0)
    slow = tq.send(pr, 30.0, dst="decode-0")
    assert ((slow.arrive_t - slow.start_t)
            > 2.0 * (fast.arrive_t - fast.start_t))


def test_decode_worker_lookup_suggests_nearest():
    from types import SimpleNamespace

    from repro.disagg import DisaggPool, DisaggSimulator, TransferQueue
    pool = DisaggPool(
        prefill_workers=[],
        decode_workers=[SimpleNamespace(name="decode-0"),
                        SimpleNamespace(name="decode-1")],
        transfer=TransferQueue())
    sim = DisaggSimulator(pool)
    assert sim._decode_worker("decode-1").name == "decode-1"
    with pytest.raises(KeyError, match="did you mean 'decode-0'"):
        sim._decode_worker("decode0")


@pytest.mark.slow
def test_disagg_decode_crash_recovers_exactly_once():
    """A decode worker dies mid-run: its in-flight generation state is
    re-prefilled, dropped hand-offs are retransmitted, and every rid
    still resolves exactly once (served or rejected-with-reason)."""
    import jax

    from repro.configs import get_smoke_config
    from repro.disagg import (DisaggSimulator, PhaseAwareRouter,
                              build_disagg_fleet)
    from repro.fleet import make_generate_scenario
    from repro.models import transformer as tfm

    cfg = get_smoke_config("stablelm-3b").replace(remat=False)
    params = tfm.init_lm(cfg, jax.random.PRNGKey(0))
    sc = make_generate_scenario("prompt-burst", 10, seed=0,
                                vocab=cfg.vocab, short_prompt=8,
                                long_prompt=16, max_new=3)
    pool = build_disagg_fleet(cfg, params, n_prefill=2, n_decode=2,
                              n_slots=2, max_seq=64)
    mid = sc.requests[len(sc.requests) // 2].arrival_s
    plan = FaultPlan.scripted([
        FaultEvent(t=mid, kind="crash", target="decode-0",
                   duration_s=0.2),
        FaultEvent(t=mid, kind="link-flap", duration_s=0.05),
    ])
    sim = DisaggSimulator(pool, router=PhaseAwareRouter(),
                          injector=FaultInjector(plan),
                          retry_policy=RetryPolicy())
    rep = sim.run(sc.requests)
    rids = [r["rid"] for r in rep.responses]
    assert sorted(rids) == list(range(10))           # none hang
    assert len(set(rids)) == len(rids)               # exactly once
    served = [r for r in rep.responses if "rejected" not in r]
    assert all(len(r["tokens"]) >= 1 for r in served)
    assert rep.summary["n_served"] + rep.summary["n_rejected"] == 10
    assert rep.summary["n_failures"] == 2    # crash + link-flap
    assert rep.summary["n_retries"] + rep.summary["n_retransmits"] > 0
