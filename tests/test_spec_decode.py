"""Self-speculative decoding: the draft/verify window must be LOSSLESS.

Emitted tokens are always the FULL model's samples under the same
(rid, position)-folded keys — the draft only decides how many of them
land per device step — so the speculative engine must byte-match the
non-speculative engine at every temperature, and the accepted-token
distribution IS the full-model sampling distribution.  These tests pin
that invariant, the acceptance/energy accounting around it, the
compile-once guarantee (depth and sampling params are traced VALUES),
and the constructor's refusal of layouts the verify chunk cannot
serve.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.serving import sampling
from repro.serving.continuous import ContinuousBatchingEngine, GenRequest
from repro.serving.sampling import SamplingParams

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    cfg = get_smoke_config("stablelm-3b").replace(remat=False, **kw)
    return cfg


def _spec_cfg(**kw):
    cfg = _cfg(**kw)
    return cfg.replace(draft_layers=max(cfg.n_layers - 1, 1))


def _params(cfg):
    return tfm.init_lm(cfg, KEY)


def _reqs(cfg, n=6, plen=8, seed=0, sp=None):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, plen) for _ in range(n)]
    return [GenRequest(rid=i, prompt=prompts[i], max_new=4 + (i % 4),
                       sampling=sp)
            for i in range(n)]


def _serve(cfg, params, reqs, **kw):
    eng = ContinuousBatchingEngine(cfg, params, n_slots=4, max_seq=64,
                                   sync_every=2, **kw)
    stats = eng.serve(reqs, prompt_len=8)
    return eng, stats


SP = SamplingParams(temperature=0.9, top_k=20, top_p=0.95, seed=7)


# ---------------------------------------------------------------------------
# losslessness: byte parity with the non-speculative path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 3])
def test_spec_byte_matches_nonspec_greedy(depth):
    cfg = _spec_cfg()
    params = _params(cfg)
    rb = _reqs(cfg)
    _serve(cfg.replace(draft_layers=0), params, rb)
    rs = _reqs(cfg)
    _, stats = _serve(cfg, params, rs, draft_depth=depth)
    assert [r.generated for r in rs] == [r.generated for r in rb]
    assert all(r.done for r in rs)
    assert stats["mode"] == "spec"


@pytest.mark.parametrize("depth", [1, 3])
def test_spec_byte_matches_nonspec_sampled(depth):
    """T>0: accepted prefixes are the full model's samples under the
    same keys, so the WHOLE stream (not just prefixes) byte-matches."""
    cfg = _spec_cfg()
    params = _params(cfg)
    rb = _reqs(cfg, sp=SP)
    _serve(cfg.replace(draft_layers=0), params, rb)
    rs = _reqs(cfg, sp=SP)
    _serve(cfg, params, rs, draft_depth=depth)
    assert [r.generated for r in rs] == [r.generated for r in rb]


# ---------------------------------------------------------------------------
# acceptance + modelled energy
# ---------------------------------------------------------------------------

def _aligned_params(cfg):
    """Zero the LAST layer's params: the residual block becomes the
    identity, so the (n_layers-1)-deep draft agrees with the full
    model almost everywhere -> high acceptance."""
    params = _params(cfg)
    pz = dict(params)
    pz["layers"] = jax.tree_util.tree_map(lambda x: x.at[-1].set(0.0),
                                          params["layers"])
    return pz


def test_aligned_draft_accepts_and_saves_energy():
    """When the draft agrees with the full model, acceptance is high
    (budget/EOS truncation keeps it below 1.0) and the modelled
    J/token drops below the greedy baseline's 1.0."""
    cfg = _spec_cfg()
    pz = _aligned_params(cfg)
    rs = _reqs(cfg)
    _, stats = _serve(cfg, pz, rs, draft_depth=3)
    assert stats["acceptance_rate"] > 0.5
    assert stats["accepted_per_step"] > 1.0
    assert stats["energy_per_token_model"] < 1.0
    # and still byte-identical to the non-speculative engine
    rb = _reqs(cfg)
    _serve(cfg.replace(draft_layers=0), pz, rb)
    assert [r.generated for r in rs] == [r.generated for r in rb]


def test_misaligned_draft_costs_energy_not_correctness():
    """Random weights: the 1-layer draft rarely matches the full
    model, so acceptance collapses and modelled J/token EXCEEDS 1.0 —
    but the stream still byte-matches (losslessness is unconditional).
    The depth controller reacts by collapsing the live depth."""
    cfg = _spec_cfg()
    params = _params(cfg)
    rs = _reqs(cfg)
    eng, stats = _serve(cfg, params, rs, draft_depth=3)
    rb = _reqs(cfg)
    _serve(cfg.replace(draft_layers=0), params, rb)
    assert [r.generated for r in rs] == [r.generated for r in rb]
    assert stats["acceptance_rate"] < 0.5
    assert stats["energy_per_token_model"] > 1.0
    assert stats["draft_depth_live"] < 3          # controller backed off
    assert eng.spec_controller.acceptance_rate < 0.5


def test_spec_stats_accounting():
    cfg = _spec_cfg()
    _, stats = _serve(cfg, _aligned_params(cfg), _reqs(cfg),
                      draft_depth=2)
    assert stats["spec_proposed"] >= stats["spec_accepted"] >= 0
    assert 0.0 <= stats["acceptance_rate"] <= 1.0
    assert stats["draft_depth"] == 2
    assert 1 <= stats["draft_depth_live"] <= 2
    assert stats["draft_layers"] == cfg.draft_layers
    # every macro step emits at least its mandatory full-model token
    assert stats["accepted_per_step"] >= 1.0


# ---------------------------------------------------------------------------
# distribution-level correctness
# ---------------------------------------------------------------------------

def test_sampler_matches_softmax_distribution():
    """The Gumbel-trick sampler draws from softmax(masked logits/T):
    empirical frequencies over many keys match the closed form."""
    v, temp, n = 12, 0.8, 4000
    logits = jax.random.normal(jax.random.PRNGKey(1), (1, v)) * 2.0
    masked = sampling.top_p_mask(
        sampling.top_k_mask(logits / temp, jnp.array([8])),
        jnp.array([0.97]))
    probs = np.asarray(jax.nn.softmax(masked, -1), np.float64)[0]

    base = jnp.asarray(
        np.stack([sampling.request_key(0, i) for i in range(n)]))
    keys = sampling.step_keys(base, jnp.zeros(n, jnp.int32))
    toks = np.asarray(sampling.sample_token(
        keys, jnp.broadcast_to(logits, (n, v)),
        jnp.full(n, temp, jnp.float32), jnp.full(n, 8, jnp.int32),
        jnp.full(n, 0.97, jnp.float32)))
    freq = np.bincount(toks, minlength=v) / n
    # total variation distance; ~1/sqrt(n) scale
    assert 0.5 * np.abs(freq - probs).sum() < 0.05


def test_spec_token_frequencies_match_nonspec():
    """Distribution-level spec correctness: pooled across seeds, the
    draft-verify engine's emitted-token frequencies match the
    full-model sampling path's.  (Byte parity implies TV distance 0 —
    this pins the distributional claim independently of ordering.)"""
    cfg = _spec_cfg()
    params = _params(cfg)
    pools = {True: [], False: []}
    for seed in range(3):
        sp = SamplingParams(temperature=1.0, top_k=30, seed=seed)
        for spec in (False, True):
            reqs = _reqs(cfg, n=4, seed=seed, sp=sp)
            if spec:
                _serve(cfg, params, reqs, draft_depth=2)
            else:
                _serve(cfg.replace(draft_layers=0), params, reqs)
            pools[spec].extend(t for r in reqs for t in r.generated)
    a = np.bincount(pools[True], minlength=cfg.vocab).astype(float)
    b = np.bincount(pools[False], minlength=cfg.vocab).astype(float)
    a, b = a / a.sum(), b / b.sum()
    assert 0.5 * np.abs(a - b).sum() < 0.05


# ---------------------------------------------------------------------------
# constructor validation + compile-once
# ---------------------------------------------------------------------------

def test_paged_pool_refuses_draft_depth():
    cfg = _spec_cfg(kv_block_size=8)
    with pytest.raises(ValueError, match="contiguous"):
        ContinuousBatchingEngine(cfg, _params(cfg), n_slots=2,
                                 max_seq=64, draft_depth=2)


def test_draft_depth_needs_draft_layers():
    cfg = _cfg()                                   # draft_layers == 0
    with pytest.raises(ValueError, match="draft_layers"):
        ContinuousBatchingEngine(cfg, _params(cfg), n_slots=2,
                                 max_seq=64, draft_depth=2)


def test_draft_layers_must_be_shallow():
    cfg = _cfg()
    with pytest.raises(ValueError, match="draft_layers"):
        cfg.replace(draft_layers=cfg.n_layers)
    with pytest.raises(ValueError):
        cfg.replace(draft_layers=-1)


def test_spec_window_compiles_once_across_values():
    """Depth and sampling params are traced VALUES: serving waves with
    different SamplingParams, then again after the controller moves the
    live depth, must never retrace the fused window."""
    cfg = _spec_cfg()
    pz = _aligned_params(cfg)
    eng = ContinuousBatchingEngine(cfg, pz, n_slots=4, max_seq=64,
                                   sync_every=2, draft_depth=3)
    eng.serve(_reqs(cfg), prompt_len=8)
    c0 = eng.decode_compile_count
    assert c0 == 1
    # different sampling values, same engine
    eng.serve(_reqs(cfg, sp=SP), prompt_len=8)
    # drive the controller's acceptance EWMA to each extreme so the
    # live depth actually moves, serving a wave at each depth
    for _ in range(12):
        eng.spec_controller.observe(accepted=0, proposed=400)
    d_low = eng.current_depth()
    eng.serve(_reqs(cfg, seed=1), prompt_len=8)
    for _ in range(12):
        eng.spec_controller.observe(accepted=400, proposed=400)
    d_high = eng.current_depth()
    eng.serve(_reqs(cfg, seed=2), prompt_len=8)
    assert d_low < d_high                       # the lever actually moves
    assert eng.decode_compile_count == c0 == 1


def test_spec_across_refill_waves_and_eos():
    """More requests than slots + an EOS id: retirement inside the
    verify chunk must fold into the done-mask machinery — streams stay
    byte-identical to the non-speculative engine across refill waves."""
    cfg = _spec_cfg()
    params = _params(cfg)
    rng = np.random.default_rng(3)
    eos = 5

    def mk():
        return [GenRequest(rid=i,
                           prompt=rng_prompts[i],
                           max_new=6, eos_id=eos)
                for i in range(7)]

    rng_prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(7)]
    rb = mk()
    eng_b = ContinuousBatchingEngine(cfg.replace(draft_layers=0),
                                     params, n_slots=3, max_seq=64,
                                     sync_every=2)
    eng_b.serve(rb, prompt_len=8)
    rs = mk()
    eng_s = ContinuousBatchingEngine(cfg, params, n_slots=3, max_seq=64,
                                     sync_every=2, draft_depth=3)
    eng_s.serve(rs, prompt_len=8)
    assert [r.generated for r in rs] == [r.generated for r in rb]
    assert eng_s.decode_compile_count == 1
