"""Sharding policy invariants: every spec is mesh-legal (divisible), no
axis used twice per spec, fallbacks engage for non-divisible dims."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch import sharding as shd
from repro.models import transformer as tfm


class FakeMesh:
    """Shape-only stand-in (param_specs only reads .shape/.axis_names)."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)
MESH_POD = FakeMesh(pod=2, data=16, model=16)


def _abstract_params(arch):
    cfg = get_config(arch)
    return cfg, jax.eval_shape(
        lambda: tfm.init_lm(cfg, jax.random.PRNGKey(0)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_legal(arch):
    cfg, params = _abstract_params(arch)
    specs = shd.param_specs(params, MESH)

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape)
        used = [s for s in spec if s is not None]
        assert len(used) == len(set(used)), f"axis reuse at {path}"
        for dim, ax in enumerate(spec):
            if ax == "model":
                assert leaf.shape[dim] % 16 == 0, \
                    f"{path}: dim {dim} ({leaf.shape[dim]}) not divisible"

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs)


def test_non_divisible_heads_fall_back():
    """recurrentgemma has 10 Q heads: with the head-aligned guard the
    q/k/v/o projections must NOT shard on model (10 % 16 != 0), even
    though the flattened 2560 output dim is divisible — sharding
    through head boundaries forces in-layer all-gathers (§Perf B)."""
    cfg, params = _abstract_params("recurrentgemma-2b")
    specs = shd.param_specs(params, MESH, cfg=cfg)
    # attention layers are at pattern positions 2, 5, ... (python list)
    attn_layer = specs["layers"][2]
    assert "model" not in tuple(attn_layer["mix"]["wq"])
    assert "model" not in tuple(attn_layer["mix"]["wk"])
    assert "model" not in tuple(attn_layer["mix"]["wo"])
    # MLP still shards
    assert attn_layer["mlp"]["w_gate"][-1] == "model"


def test_head_aligned_shards_when_divisible():
    """internlm2: 48 Q heads / 8 KV heads on tp=16 -> q/o shard, k/v
    replicate (8 % 16 != 0)."""
    cfg, params = _abstract_params("internlm2-20b")
    specs = shd.param_specs(params, MESH, cfg=cfg)
    mix = specs["layers"]["mix"]
    assert mix["wq"][-1] == "model"
    assert mix["wo"][-2] == "model"
    assert "model" not in tuple(mix["wk"])


def test_fsdp_adds_data_axis():
    cfg, params = _abstract_params("llama3-405b")
    specs = shd.param_specs(params, MESH, cfg=cfg, fsdp=True)
    wq = specs["layers"]["mix"]["wq"]          # [L, D, H*hd]
    assert "model" in wq and "data" in wq
    used = [s for s in wq if s is not None]
    assert len(used) == len(set(used))


def test_granite_expert_dim_falls_back_to_ffn():
    """40 experts % 16 != 0 -> expert dim replicated, d_ff sharded."""
    cfg, params = _abstract_params("granite-moe-3b-a800m")
    specs = shd.param_specs(params, MESH)
    wg = specs["layers"]["moe"]["w_gate"]     # [L, E, D, F]
    assert wg[1] is None                      # expert dim not sharded
    assert wg[-1] == "model"                  # 512 d_ff shards


def test_dbrx_expert_dim_shards():
    """16 experts % 16 == 0 -> expert-parallel."""
    cfg, params = _abstract_params("dbrx-132b")
    specs = shd.param_specs(params, MESH)
    wg = specs["layers"]["moe"]["w_gate"]     # [L, E, D, F]
    assert wg[1] == "model"


def test_tokens_and_cache_specs():
    cfg = get_config("internlm2-20b")
    assert shd.tokens_spec(MESH, 256) == P("data", None)
    assert shd.tokens_spec(MESH_POD, 256) == P(("pod", "data"), None)
    # batch=1 -> batch unsharded
    assert shd.tokens_spec(MESH, 1) == P(None, None)

    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 128, 1024))
    specs = shd.cache_specs(cfg, cache, MESH, 128)
    kv = specs.layers.kv
    assert kv.k[1] == "data"                  # batch sharded
    assert kv.k[3] is None                    # 8 kv heads % 16 != 0

    # long-context batch=1: sequence dim takes the data axis
    cache1 = jax.eval_shape(lambda: tfm.init_cache(cfg, 1, 4096))
    specs1 = shd.cache_specs(cfg, cache1, MESH, 1)
    assert specs1.layers.kv.k[1] is None
    assert specs1.layers.kv.k[2] == "data"    # sequence-sharded decode


def test_mla_cache_latent_spec():
    cfg = get_config("minicpm3-4b")
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 128, 512))
    specs = shd.cache_specs(cfg, cache, MESH, 128)
    assert specs.layers.kv.c_kv[1] == "data"


def test_ssd_state_spec():
    cfg = get_config("mamba2-780m")
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 128, 512))
    specs = shd.cache_specs(cfg, cache, MESH, 128)
    assert specs.layers.rec.h[1] == "data"    # [L,B,H,hd,N]
    assert specs.layers.rec.h[2] == "model"   # 48 heads shard
