"""In-graph gated serving step: static-shape admission + bucketed
full-model execution inside one jit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DecayingThreshold
from repro.models import distilbert
from repro.serving.gated import (GateParams, make_gated_classify_step,
                                 serve_gated)
from repro.training import ClassificationData, train_classifier


@pytest.fixture(scope="module")
def model():
    cfg = distilbert.config(n_layers=3, d_model=64, n_heads=4, d_ff=128,
                            vocab=600, max_pos=48)
    params = distilbert.init(cfg, jax.random.PRNGKey(0))
    data = ClassificationData(vocab=600, seq_len=32, seed=11)
    params, _ = train_classifier(cfg, params, data.train_batches(32),
                                 steps=100, verbose=False)
    return cfg, params, data


def test_gated_step_shapes_and_capacity(model):
    cfg, params, data = model
    toks, labels, _ = data.sample(64)
    step = make_gated_classify_step(cfg, capacity=16)
    pred, admitted, ent = step(params, jnp.asarray(toks), 0.5, 0.3, 0.0)
    assert pred.shape == (64,) and admitted.shape == (64,)
    assert int(jnp.sum(admitted)) <= 16          # capacity respected
    assert bool(jnp.isfinite(ent).all())


def test_gate_tau_monotone(model):
    """Stricter tau admits fewer requests (rule='le')."""
    cfg, params, data = model
    toks, _, _ = data.sample(64)
    step = make_gated_classify_step(cfg, capacity=64)
    admits = []
    for tau in (0.05, 0.3, 0.9):
        _, a, _ = step(params, jnp.asarray(toks), tau, 0.0, 0.0)
        admits.append(int(jnp.sum(a)))
    assert admits[0] <= admits[1] <= admits[2]


def test_gated_pred_sources(model):
    """Admitted rows carry full-model predictions, skipped rows carry
    proxy predictions."""
    cfg, params, data = model
    toks, _, _ = data.sample(32)
    x = jnp.asarray(toks)
    step = make_gated_classify_step(cfg, capacity=32)
    pred, admitted, _ = step(params, x, 0.9, 0.0, 0.0)

    full = jnp.argmax(distilbert.logits(cfg, params, x), -1)
    proxy = jnp.argmax(
        distilbert.early_exit_logits(cfg, params, x, exit_layer=2), -1)
    adm = np.asarray(admitted)
    np.testing.assert_array_equal(np.asarray(pred)[adm],
                                  np.asarray(full)[adm])
    np.testing.assert_array_equal(np.asarray(pred)[~adm],
                                  np.asarray(proxy)[~adm])


def test_serve_gated_closed_loop(model):
    cfg, params, data = model
    toks, labels, _ = data.sample(300)
    th = DecayingThreshold(tau0=0.9, tau_inf=0.25, k=0.02)
    preds, admits, ents = serve_gated(cfg, params, toks,
                                      tau_schedule=th, batch=64)
    acc = float(np.mean(preds == labels))
    assert 0.0 < admits.mean() < 1.0
    assert acc > 0.7
    # later batches are stricter (tau decayed)
    assert admits[:64].mean() >= admits[-64:].mean() - 0.25
